"""Transport pipeline: mode semantics, stats, chunking, ECRT exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as CH
from repro.core import transport as T


def _cfg(**kw):
    ch = kw.pop("channel", CH.ChannelConfig(snr_db=10.0))
    return T.TransportConfig(channel=ch, **kw)


@pytest.fixture(scope="module")
def payload():
    return jax.random.uniform(jax.random.PRNGKey(0), (4096,), minval=-0.99, maxval=0.99)


def test_perfect_is_identity(payload):
    out, st = T.transmit_flat(payload, jax.random.PRNGKey(1), _cfg(mode="perfect"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(payload))
    assert float(st.ber) == 0.0


def test_naive_produces_unbounded_garbage(payload):
    """The paper's collapse baseline: raw bit errors give NaN/huge values."""
    out, st = T.transmit_flat(payload, jax.random.PRNGKey(1), _cfg(mode="naive"))
    assert float(st.ber) > 0.01
    assert (~jnp.isfinite(out)).any() or float(jnp.abs(out).max()) > 2.0


def test_approx_is_bounded_and_finite(payload):
    """Fig. 1: with bit-30 forced to 0 the received gradient is always a
    finite float with |g| < 2 — no NaN/Inf can be decoded."""
    for snr in (0.0, 10.0, 20.0):
        cfg = _cfg(mode="approx", channel=CH.ChannelConfig(snr_db=snr))
        out, st = T.transmit_flat(payload, jax.random.PRNGKey(2), cfg)
        assert bool(jnp.isfinite(out).all())
        assert float(jnp.abs(out).max()) < 2.0


def test_approx_error_shrinks_with_snr(payload):
    errs = []
    for snr in (5.0, 15.0, 25.0):
        cfg = _cfg(mode="approx", channel=CH.ChannelConfig(snr_db=snr))
        out, _ = T.transmit_flat(payload, jax.random.PRNGKey(3), cfg)
        errs.append(float(jnp.mean(jnp.abs(out - payload))))
    assert errs[0] > errs[1] > errs[2]


def test_chunked_matches_unchunked_semantics(payload):
    """Chunking changes RNG stream (per-chunk keys) but must preserve the
    distributional contract: same BER scale, bounded outputs, exact stats
    bookkeeping."""
    cfg = _cfg(mode="approx", chunk_elems=1024)
    out, st = T.transmit_flat(payload, jax.random.PRNGKey(4), cfg)
    assert out.shape == payload.shape
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).max()) < 2.0
    cfg0 = _cfg(mode="approx")
    out0, st0 = T.transmit_flat(payload, jax.random.PRNGKey(4), cfg0)
    assert float(st.n_bits) == float(st0.n_bits)
    assert float(st.data_symbols) == float(st0.data_symbols)
    assert float(st.ber) == pytest.approx(float(st0.ber), rel=0.2)


def test_pytree_roundtrip_structure():
    tree = {"a": jnp.ones((3, 5)), "b": [jnp.zeros((7,)), jnp.full((2, 2), 0.5)]}
    out, st = T.transmit_pytree(tree, jax.random.PRNGKey(5), _cfg(mode="perfect"))
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_ecrt_real_chain_is_exact():
    """Rate-1/2 LDPC + retransmission delivers exact bits (paper: 'all the
    bits are received correctly by the PS')."""
    x = jax.random.uniform(jax.random.PRNGKey(6), (512,), minval=-1, maxval=1)
    cfg = _cfg(mode="ecrt", channel=CH.ChannelConfig(snr_db=12.0), max_tx=6)
    out, st = T.transmit_flat(x, jax.random.PRNGKey(7), cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert float(st.ber) == 0.0
    assert float(st.transmissions) >= 1.0


def test_ecrt_analytic_model(payload):
    cfg = _cfg(mode="ecrt", simulate_fec=False, ecrt_expected_tx=1.25)
    out, st = T.transmit_flat(payload, jax.random.PRNGKey(8), cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(payload))
    # rate 1/2 => 2x symbols, times E[tx]
    k = cfg.scheme.bits_per_symbol
    assert float(st.data_symbols) == pytest.approx(
        2 * payload.size * 32 / k * 1.25)


def test_bf16_wire_halves_airtime_and_stays_bounded(payload):
    """Beyond-paper 16-bit uplink: bf16 shares f32's exponent layout, so the
    bit-clamp applies verbatim at half the symbols."""
    f32 = _cfg(mode="approx")
    b16 = _cfg(mode="approx", wire_dtype="bfloat16")
    out32, st32 = T.transmit_flat(payload, jax.random.PRNGKey(9), f32)
    out16, st16 = T.transmit_flat(payload, jax.random.PRNGKey(9), b16)
    assert float(st16.data_symbols) == pytest.approx(float(st32.data_symbols) / 2)
    assert bool(jnp.isfinite(out16).all())
    assert float(jnp.abs(out16).max()) < 2.0
    # error scale comparable (clamp works identically on the bf16 exponent)
    assert float(jnp.abs(out16 - payload).mean()) < 3 * max(
        float(jnp.abs(out32 - payload).mean()), 1e-3)


def test_bf16_wire_noiseless_is_pure_quantization(payload):
    cfg = _cfg(mode="approx", wire_dtype="bfloat16",
               channel=CH.ChannelConfig(snr_db=80.0, fading="awgn"))
    out, st = T.transmit_flat(payload, jax.random.PRNGKey(10), cfg)
    want = payload.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
