"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and one
decode step on CPU with finite outputs and correct shapes."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.models import registry as R
from repro.optim.sgd import sgd as make_sgd


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduced(arch, key):
    cfg = get_config(arch).reduced()
    params = R.init_params(key, cfg)
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64, global_batch=2)
    batch = R.make_batch(cfg, shape, key)

    loss0 = R.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss0))
    # one SGD step on the same batch must reduce the loss
    grads = jax.grad(R.loss_fn)(params, batch, cfg)
    opt = make_sgd(0.5)
    params2, _ = opt.update(grads, opt.init(params), params)
    loss1 = R.loss_fn(params2, batch, cfg)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0), (arch, float(loss0), float(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_output_shape(arch, key):
    cfg = get_config(arch).reduced()
    params = R.init_params(key, cfg)
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=32, global_batch=2)
    batch = R.make_batch(cfg, shape, key)
    logits, aux = R.forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_reduced(arch, key):
    cfg = get_config(arch).reduced()
    params = R.init_params(key, cfg)
    cache = R.init_cache(cfg, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = R.decode_step(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache2) == jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_decode_matches_forward(arch, key):
    """Teacher-forced decode over a short sequence reproduces the training
    forward's logits (cache correctness)."""
    cfg = get_config(arch).reduced()
    params = R.init_params(key, cfg)
    S = 12
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (2, cfg.encoder_seq, cfg.d_model))
    ref_logits, _ = R.forward(params, batch, cfg)

    cache = R.init_cache(cfg, 2, S)
    outs = []
    for t in range(S):
        lg, cache = R.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    import numpy as np

    # atol 5e-2: bf16 params + different reduction orders (fused scan in
    # decode vs batched forward) put the rare worst element just past 3e-2
    # on CPU (falcon-mamba: 1/12288 at 0.0342).
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref_logits), rtol=3e-2, atol=5e-2)


def test_ring_cache_equals_full_within_window(key):
    """For contexts shorter than the window, ring (sliding) decode must equal
    full-cache decode — the long_500k correctness invariant."""
    cfg = get_config("yi-6b").reduced(decode_window=32)
    params = R.init_params(key, cfg)
    S = 16  # < window
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size, jnp.int32)
    cache_f = R.init_cache(cfg, 2, S)
    cache_r = R.init_cache(cfg, 2, cfg.decode_window)
    import numpy as np

    for t in range(S):
        lf, cache_f = R.decode_step(params, cache_f, tokens[:, t : t + 1],
                                    jnp.int32(t), cfg, ring=False)
        lr, cache_r = R.decode_step(params, cache_r, tokens[:, t : t + 1],
                                    jnp.int32(t), cfg, ring=True)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), rtol=2e-3, atol=2e-3)


def test_ring_cache_wraps(key):
    """Decoding past the window must keep working (slots are reused)."""
    cfg = get_config("yi-6b").reduced(decode_window=8)
    params = R.init_params(key, cfg)
    cache = R.init_cache(cfg, 1, cfg.decode_window)
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(20):  # 2.5 wraps
        lg, cache = R.decode_step(params, cache, tok, jnp.int32(t), cfg, ring=True)
        assert bool(jnp.isfinite(lg).all())
