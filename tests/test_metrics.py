"""Gates for the scale-ready metrics layer (``repro.obs.sketch`` /
``repro.obs.metrics`` / ``tools.bench_diff``).

The load-bearing invariants, in order:

1. **Sketches are exact integer objects.** Bucket counts are integers
   computed on device, so merge is exactly associative and commutative,
   the same observations bucketed eagerly / under jit / under vmap are
   bit-identical, and quantile estimates stay within each layout's
   documented error bound against ``np.quantile(..., method="lower")``.

2. **Sketches are neutral.** ``sketches=True`` on either engine changes
   no numeric result — the device reduction reads the round key only
   through the reserved ``OBS_KEY_LANE`` and consumes arrays the round
   already produced.

3. **Lines are cohort-independent.** The serialized per-round sketch
   group has the same structure (and essentially the same size) at 64
   and 1024 clients.

4. **The schema versioning holds.** v1 ledgers still read; a v1-stamped
   ledger carrying v2-only round fields is rejected with a
   ``path:lineno:`` locator; ``detail="sketch"`` suppresses event lines.

5. **The bench sentry fires.** ``tools.bench_diff`` accepts an artifact
   matching its baseline within tolerances and exits non-zero on a
   seeded synthetic regression.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import config as cnn_config
from repro.core import channel as CH
from repro.core import keylanes
from repro.core import transport as T
from repro.data import synth_mnist
from repro.fl import partition
from repro.fl.async_engine import run_fl_buffered
from repro.fl.loop import run_fl
from repro.link import scenario as S
from repro.obs import ledger as L
from repro.obs import metrics as M
from repro.obs import records as R
from repro.obs.sketch import BucketLayout, Sketch, bucket_counts, \
    reservoir_sample, reservoir_tags

BER_LAY = M.DEFAULT_LAYOUTS["ber"]
SNR_LAY = M.DEFAULT_LAYOUTS["snr_db"]


# --------------------------------------------------------------------------
# sketch primitives
# --------------------------------------------------------------------------


def _lognormal(n, seed=0):
    r = np.random.default_rng(seed)
    return np.clip(np.exp(r.normal(-6.0, 2.5, n)).astype(np.float32),
                   2e-8, 0.9)


def test_merge_associative_commutative():
    vals = _lognormal(600)
    chunks = np.split(vals, 3)
    parts = [Sketch(BER_LAY).observe(c) for c in chunks]
    whole = Sketch(BER_LAY).observe(vals)
    ab_c = parts[0].merge(parts[1]).merge(parts[2])
    a_bc = parts[0].merge(parts[1].merge(parts[2]))
    cba = parts[2].merge(parts[1]).merge(parts[0])
    assert ab_c == a_bc == cba == whole


@pytest.mark.parametrize("q", [0.05, 0.25, 0.5, 0.9, 0.95, 0.99])
def test_quantile_bound_log_layout(q):
    vals = _lognormal(2000, seed=1)
    sk = Sketch(BER_LAY).observe(vals)
    exact = float(np.quantile(vals, q, method="lower"))
    rel = abs(sk.quantile(q) - exact) / exact
    # 1e-5 slack: a ranked value on a bucket edge can overshoot the
    # analytic bound by the float32 edge-rounding error.
    assert rel <= BER_LAY.error_bound() + 1e-5


@pytest.mark.parametrize("q", [0.05, 0.5, 0.95, 0.99])
def test_quantile_bound_linear_layout(q):
    r = np.random.default_rng(2)
    vals = np.clip(r.normal(12.0, 9.0, 2000),
                   SNR_LAY.lo, SNR_LAY.hi).astype(np.float32)
    sk = Sketch(SNR_LAY).observe(vals)
    exact = float(np.quantile(vals, q, method="lower"))
    assert abs(sk.quantile(q) - exact) <= SNR_LAY.error_bound() + 1e-5


def test_bucket_counts_eager_jit_vmap_identical():
    vals = jnp.asarray(_lognormal(512, seed=3).reshape(4, 128))
    eager = np.stack([np.asarray(bucket_counts(v, BER_LAY)) for v in vals])
    jitted = np.stack([np.asarray(
        jax.jit(lambda v: bucket_counts(v, BER_LAY))(v)) for v in vals])
    vmapped = np.asarray(
        jax.vmap(lambda v: bucket_counts(v, BER_LAY))(vals))
    assert eager.dtype == np.int32
    np.testing.assert_array_equal(eager, jitted)
    np.testing.assert_array_equal(eager, vmapped)


def test_under_overflow_and_mask_slots():
    lay = BucketLayout("x", "log", 1e-4, 1.0, 8)
    vals = jnp.asarray([0.0, 1e-6, 0.5, 2.0, 0.25], jnp.float32)
    mask = jnp.asarray([True, True, True, True, False])
    c = np.asarray(bucket_counts(vals, lay, mask=mask))
    assert c.shape == (lay.n + 2,)
    assert c[lay.n] == 2  # zero + 1e-6 underflow
    assert c[lay.n + 1] == 1  # 2.0 overflow
    assert c.sum() == 4  # the masked 0.25 never lands
    sk = Sketch(lay, c)
    assert sk.quantile(0.0) == 0.0  # log-layout underflow reads 0.0
    assert sk.quantile(1.0) == lay.hi  # overflow reads hi


def test_reservoir_tags_match_per_client_fold_in_loop():
    key = jax.random.PRNGKey(7)
    n = 16
    batched = np.asarray(reservoir_tags(key, n))
    loop = np.asarray([
        jax.random.uniform(
            jax.random.fold_in(key, keylanes.OBS_KEY_LANE + i))
        for i in range(n)])
    np.testing.assert_array_equal(batched, loop)
    # the k smallest tags are a deterministic function of the key alone
    tags, idx = reservoir_sample(jnp.asarray(batched), 4)
    np.testing.assert_array_equal(
        np.asarray(idx), np.argsort(batched)[:4])


def test_sketch_roundtrip_and_layout_mismatch():
    sk = Sketch(BER_LAY).observe(_lognormal(64, seed=4))
    again = Sketch.from_dict(json.loads(json.dumps(sk.to_dict())))
    assert again == sk
    with pytest.raises(ValueError, match="layouts differ"):
        sk.merge(Sketch(SNR_LAY))


# --------------------------------------------------------------------------
# cohort independence of the serialized round group
# --------------------------------------------------------------------------


def _synthetic_round(n, seed=0):
    key = jax.random.PRNGKey(seed)
    snr = jax.random.uniform(jax.random.fold_in(key, 1), (n,),
                             minval=-5.0, maxval=35.0)
    return dict(
        key=key, snr_db=snr, est_db=snr + 0.5,
        ber=jnp.clip(10.0 ** (-(snr + 20.0) / 10.0), 1e-7, 1.0),
        airtime_s=0.01 + 0.001 * jnp.arange(n, dtype=jnp.float32),
        mode=jnp.zeros((n,), jnp.int32),
        active=jnp.ones((n,), jnp.float32))


def test_round_group_structure_is_cohort_independent():
    groups = {}
    for n in (64, 1024):
        syn = _synthetic_round(n)
        key = syn.pop("key")
        groups[n] = M.RoundSketcher(n).round_group(key, **syn)
    shape = {n: {m: len(g["counts"]) for m, g in grp.items()
                 if m != "exemplars"} for n, grp in groups.items()}
    assert shape[64] == shape[1024]
    size = {n: len(json.dumps(grp)) for n, grp in groups.items()}
    assert size[1024] <= size[64] * 1.5  # formatting noise only
    for grp in groups.values():  # exemplar lists stay k-bounded
        assert len(grp["exemplars"]["worst_ber"]) <= 4
        assert len(grp["exemplars"]["reservoir"]) <= 4


# --------------------------------------------------------------------------
# engine neutrality + ledger schema v2
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def world():
    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    return cx, cy, ti, tl


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(cnn_config(), lr=0.1)


def _tc():
    return T.TransportConfig(mode="approx",
                             channel=CH.ChannelConfig(snr_db=10.0))


_KW = dict(n_rounds=3, batch_per_round=8, eval_every=2, seed=3)


@pytest.fixture(scope="module")
def sync_pair(cfg, world, tmp_path_factory):
    """(sketched run, bare twin, ledger path) on the sync engine."""
    cx, cy, ti, tl = world
    path = str(tmp_path_factory.mktemp("metrics") / "sync.jsonl")
    scen = dataclasses.replace(S.get_scenario("vehicular"),
                               ecrt_expected_tx=2.0)
    res = run_fl(cfg, _tc(), cx, cy, ti, tl, scenario=scen, ledger=path,
                 sketches=True, **_KW)
    bare = run_fl(cfg, _tc(), cx, cy, ti, tl, scenario=scen, **_KW)
    return res, bare, path


@pytest.fixture(scope="module")
def async_pair(cfg, world, tmp_path_factory):
    """(sketched run, bare twin, ledger path) on the buffered engine."""
    cx, cy, ti, tl = world
    path = str(tmp_path_factory.mktemp("metrics_async") / "async.jsonl")
    scen = dataclasses.replace(S.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(_KW, scenario=scen, buffer_k=2, staleness="polynomial")
    res = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, ledger=path,
                          sketches=True, **kw)
    bare = run_fl_buffered(cfg, _tc(), cx, cy, ti, tl, **kw)
    return res, bare, path


def test_sync_sketches_neutral(sync_pair):
    res, bare, _ = sync_pair
    assert res.accuracy == bare.accuracy
    assert res.airtime_s == bare.airtime_s
    assert res.link == bare.link


def test_async_sketches_neutral(async_pair):
    res, bare, _ = async_pair
    assert res.accuracy == bare.accuracy
    assert res.airtime_s == bare.airtime_s
    assert res.event_s == bare.event_s
    assert res.link == bare.link


@pytest.mark.parametrize("pair", ["sync_pair", "async_pair"])
def test_ledger_carries_sketch_groups(pair, request):
    _, _, path = request.getfixturevalue(pair)
    assert L.validate_ledger(path) == []
    data = L.read_ledger(path)
    assert data.rounds and all(r.sketches is not None for r in data.rounds)
    for rec in data.rounds:
        for m, g in rec.sketches.items():
            if m == "exemplars":
                continue
            assert g["total"] == sum(g["counts"])
    summary = data.summary["sketches"]
    assert summary["snr_db"]["total"] > 0
    if pair == "async_pair":  # host-side staleness observations
        assert summary["staleness"]["total"] > 0


def test_sketches_require_a_scenario(cfg, world):
    cx, cy, ti, tl = world
    with pytest.raises(ValueError, match="scenario"):
        run_fl(cfg, _tc(), cx, cy, ti, tl, sketches=True, **_KW)


def test_detail_sketch_suppresses_events(tmp_path):
    led = L.RunLedger(tmp_path / "d.jsonl", detail="sketch")
    assert led.events is False
    led.write_manifest({"fingerprint": "x", "algorithm": "y",
                        "provenance": L.provenance()})
    led.write_event(R.EventRecord(t=0.0, kind="wave", dur=1.0))
    led.close()
    lines = (tmp_path / "d.jsonl").read_text().splitlines()
    assert len(lines) == 1
    assert json.loads(lines[0])["detail"] == "sketch"
    with pytest.raises(ValueError, match="detail"):
        L.RunLedger(tmp_path / "e.jsonl", detail="medium")


def test_v1_ledger_with_v2_field_rejected_per_line(tmp_path, sync_pair):
    _, _, path = sync_pair
    lines = open(path).read().splitlines()
    downgraded = tmp_path / "mixed.jsonl"
    first = json.loads(lines[0])
    first["schema"] = 1
    downgraded.write_text("\n".join([json.dumps(first)] + lines[1:]) + "\n")
    problems = L.validate_ledger(str(downgraded))
    assert len(problems) == 1
    assert problems[0].startswith(f"{downgraded}:2:")
    assert "mixed-version" in problems[0]
    # a true v1 ledger (no v2 fields anywhere) still reads
    v1_lines = [json.dumps(first)]
    for line in lines[1:]:
        obj = json.loads(line)
        obj.pop("sketches", None)  # rounds and the summary both carry it
        v1_lines.append(json.dumps(obj))
    v1 = tmp_path / "v1.jsonl"
    v1.write_text("\n".join(v1_lines) + "\n")
    assert L.validate_ledger(str(v1)) == []


# --------------------------------------------------------------------------
# metrics registry + OpenMetrics exposition
# --------------------------------------------------------------------------


def test_openmetrics_render_shape():
    reg = M.MetricsRegistry()
    reg.counter("repro_rounds", "rounds run")
    reg.inc("repro_rounds", 5)
    reg.gauge("repro_final_accuracy", 0.91, "final accuracy")
    reg.histogram("repro_ber", Sketch(BER_LAY).observe(_lognormal(128)),
                  "per-client BER")
    text = reg.render()
    assert text.endswith("# EOF\n")
    assert "# TYPE repro_rounds counter" in text
    assert "repro_rounds_total 5" in text
    assert "repro_final_accuracy 0.91" in text
    # histogram buckets must be cumulative and end at +Inf == _count
    bucket_counts_ = [float(ln.rsplit(" ", 1)[1])
                      for ln in text.splitlines()
                      if ln.startswith("repro_ber_bucket")]
    assert bucket_counts_ == sorted(bucket_counts_)
    count = [float(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
             if ln.startswith("repro_ber_count")]
    assert bucket_counts_[-1] == count[0] == 128.0


def test_registry_from_ledger_merges_rounds(sync_pair):
    _, _, path = sync_pair
    data = L.read_ledger(path)
    text = M.registry_from_ledger(path).render()
    assert text.endswith("# EOF\n")
    assert f"repro_rounds_total {len(data.rounds)}" in text
    # the merged histogram count equals the sum of the round totals
    per_round = sum(r.sketches["snr_db"]["total"] for r in data.rounds)
    assert f"repro_client_snr_db_count {per_round}" in text


# --------------------------------------------------------------------------
# bench-diff sentry
# --------------------------------------------------------------------------


def _write_json(path, obj):
    path.write_text(json.dumps(obj))
    return str(path)


def test_bench_diff_ok_then_seeded_regression(tmp_path, capsys):
    from tools import bench_diff
    base = {"gates": {"fast": True}, "ratio": 5.0, "wall_s": 1.0}
    spec = {"BENCH_x.json": {"gates.fast": {"equals": True},
                             "ratio": {"min": 4.0, "rel": 0.05}}}
    baseline = _write_json(tmp_path / "BENCH_x.json", base)
    spec_path = _write_json(tmp_path / "spec.json", spec)
    ok = _write_json(tmp_path / "cur_ok.json",
                     {**base, "ratio": 5.1, "wall_s": 99.0})
    assert bench_diff.main([ok, baseline, "--spec", spec_path]) == 0
    # seeded regression: gate flipped + ratio below floor
    bad = _write_json(tmp_path / "cur_bad.json",
                      {**base, "gates": {"fast": False}, "ratio": 3.2})
    assert bench_diff.main([bad, baseline, "--spec", spec_path]) == 1
    out = capsys.readouterr().out
    assert "DRIFT" in out and "gates.fast" in out and "ratio" in out
    # a spec'd key missing from the current artifact is always drift
    missing = _write_json(tmp_path / "cur_missing.json", {"ratio": 5.0})
    assert bench_diff.main([missing, baseline, "--spec", spec_path]) == 1


def test_bench_diff_committed_baselines_match_repo_artifacts():
    """The committed baselines must agree with themselves (sanity: the
    sentry exits 0 when current == baseline)."""
    from tools import bench_diff
    base = bench_diff.BASELINE_DIR / "BENCH_kernel_throughput.json"
    assert base.exists()
    assert bench_diff.main([str(base), str(base)]) == 0
