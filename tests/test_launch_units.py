"""Launch-layer unit tests that need no devices: sharding rules, input
specs for all 40 (arch x shape) combos, the HLO collective parser, the
latency model, attention variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core import transport as T
from repro.core.latency import PhyTimings, round_airtime
from repro.models import registry as R


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_and_caches_build(arch, shape_name):
    """eval_shape-level coverage of every (arch x shape) pair — cheap proof
    that params/inputs/caches are constructible for all 40 combos."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = R.supports_shape(cfg, shape)
    if not ok:
        pytest.skip(reason)
    specs = R.input_specs(cfg, shape)
    assert "tokens" in specs
    if shape.kind == "train":
        assert specs["labels"].shape == specs["tokens"].shape
    if shape.kind == "decode":
        assert specs["tokens"].shape == (shape.global_batch, 1)
        clen = R.cache_len_for(cfg, shape)
        if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
            assert clen == cfg.decode_window  # ring cache, not 500k
        cache = jax.eval_shape(lambda: R.init_cache(cfg, shape.global_batch, clen))
        assert len(jax.tree_util.tree_leaves(cache)) > 0
    # params build abstractly for the FULL config (no allocation)
    params = jax.eval_shape(lambda: R.init_params(jax.random.PRNGKey(0), cfg))
    n = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    assert n > 1e6


def test_param_scale_sanity():
    """Full-config param counts are in the advertised ballpark."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.3e12),
        "yi-6b": (5e9, 7.5e9),
        "deepseek-coder-33b": (30e9, 37e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        params = jax.eval_shape(lambda c=cfg: R.init_params(jax.random.PRNGKey(0), c))
        n = float(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)))
        assert lo < n < hi, (arch, n)


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
HloModule jit_step

%region_0.2 (arg: f32[8]) -> f32[8] {
  %x = f32[16,128]{1,0} all-gather(%p), dimensions={0}
  %y = f32[128]{0} all-reduce(%q), to_apply=%add
}

%region_1.3 (arg: s32[]) -> pred[] {
  %c = s32[] constant(28)
  %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: f32[2]) -> f32[2] {
  %w = (s32[], f32[8]) while(%t), condition=%region_1.3, body=%region_0.2
  %z = f32[64,64]{1,0} all-to-all(%r), dimensions={1}
}
"""
    out = parse_collectives(hlo, default_trip=99)
    ag = 16 * 128 * 4 * 28  # all-gather in body x trip count 28
    ar = 2 * 128 * 4 * 28  # all-reduce counts 2x (ring)
    a2a = 64 * 64 * 4  # entry: once
    assert out["all-gather"] == ag
    assert out["all-reduce"] == ar
    assert out["all-to-all"] == a2a
    assert out["_total"] == ag + ar + a2a


def test_sharding_rules_divisibility():
    """Every param of every arch gets a spec whose axes divide the dims."""
    import math

    from repro.launch import sharding as sh

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    mesh = FakeMesh()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        params = jax.eval_shape(lambda c=cfg: R.init_params(jax.random.PRNGKey(0), c))
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        for path, leaf in flat:
            spec = sh.param_rules(jax.tree_util.keystr(path), leaf.shape, cfg,
                                  mesh, fsdp=True)
            for dim, axes in zip(leaf.shape, tuple(spec)):
                if axes is None:
                    continue
                ax = (axes,) if isinstance(axes, str) else axes
                n = math.prod(mesh.shape[a] for a in ax)
                assert dim % n == 0, (arch, jax.tree_util.keystr(path), spec)


def test_latency_model_orderings():
    t = PhyTimings()
    n_bits = 32 * 100_000
    approx = T.TxStats(*map(jnp.float32, (n_bits / 2, 1, 123, n_bits)))
    ecrt = T.TxStats(*map(jnp.float32, (2 * n_bits / 2 * 1.2, 1.2, 0, n_bits)))
    ta = float(round_airtime(approx, t, "approx"))
    te = float(round_airtime(ecrt, t, "ecrt"))
    assert te > 2.0 * ta  # rate-1/2 + retx + FEC stall
    # higher-order modulation shrinks airtime
    approx256 = T.TxStats(*map(jnp.float32, (n_bits / 8, 1, 123, n_bits)))
    assert float(round_airtime(approx256, t, "approx")) < ta


def test_blockwise_attention_grad_matches():
    """Gradients (not just outputs) agree between attention impls."""
    from repro.models import attention as A

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 16))

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)

    g1 = jax.grad(loss(lambda *a: A.attend_train(*a, causal=True)))(q, k, v)
    g2 = jax.grad(loss(lambda *a: A.attend_train_blockwise(
        *a, causal=True, block_q=64, block_kv=64)))(q, k, v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)
