"""Property battery for the buffered engine's aggregation + event layer.

Three families, per the async-engine contract:

* **buffer algebra** — ``weighted_buffer_mean`` is invariant to the order
  updates arrived in (entries are canonicalized by wave id before any
  float op), staleness weights are non-negative / 1 at zero staleness /
  non-increasing, and a buffer of identical payloads aggregates to that
  payload regardless of the weights (the normalization property);
* **arrival determinism** — compute-time, churn, and idle draws are
  bit-stable between jit and eager and independent of cohort batching
  (``draws(key, M)[:m] == draws(key, m)``: every client folds its own
  index, so who else is in the wave cannot perturb a client's draw);
* **schedule stability** — the buffered engine's event clock is
  reproducible: same seed, same ``FLResult`` (timestamps included).

Runs under real ``hypothesis`` when installed, else the deterministic
stub in ``conftest.py`` (which these tests' ``booleans``/``tuples``
strategies extend).
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl.async_engine import (STALENESS_KINDS, staleness_weight,
                                   weighted_buffer_mean)
from repro.link import dynamics as D

# ------------------------------------------------------------ buffer algebra


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=10_000))
def test_weighted_buffer_mean_permutation_invariant(n_waves, seed):
    """Arrival order must not change the aggregate, bit for bit."""
    rng = np.random.default_rng(seed)
    entries = []
    for w in range(n_waves):
        hat = {"g": jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)}
        wvec = jnp.asarray(
            rng.random(4) * (rng.random(4) < 0.7), jnp.float32)
        entries.append((w, hat, wvec))
    ref = weighted_buffer_mean(entries)
    shuffled = list(entries)
    random.Random(seed).shuffle(shuffled)
    out = weighted_buffer_mean(shuffled)
    assert jnp.array_equal(ref["g"], out["g"])


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(STALENESS_KINDS),
       st.integers(min_value=0, max_value=100),
       st.floats(min_value=0.1, max_value=2.0))
def test_staleness_weight_contract(kind, s, alpha):
    """Non-negative, exactly 1 at s=0, non-increasing in s; the constant
    kind is exactly 1 everywhere (the synchronous-equivalence setting)."""
    w = float(staleness_weight(s, kind, alpha))
    assert w >= 0.0
    assert float(staleness_weight(0, kind, alpha)) == 1.0
    assert w <= float(staleness_weight(max(s - 1, 0), kind, alpha)) + 1e-7
    if kind == "constant":
        assert w == 1.0


@settings(max_examples=15, deadline=None)
@given(st.tuples(st.sampled_from(STALENESS_KINDS), st.booleans()),
       st.integers(min_value=0, max_value=10_000))
def test_identical_updates_aggregate_to_identity(kind_full, seed):
    """A buffer of waves all carrying payload X aggregates to X under any
    staleness weighting — the weights normalize away."""
    kind, full_mask = kind_full
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 8)), jnp.float32)
    hat = jnp.repeat(x, 4, axis=0)
    entries = []
    for w in range(3):
        mask = np.ones(4, np.float32)
        if not full_mask:
            mask[rng.integers(0, 4)] = 0.0
        om = float(staleness_weight(w, kind, 0.5))
        entries.append((w, {"g": hat}, jnp.asarray(mask * np.float32(om))))
    out = weighted_buffer_mean(entries)
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(x[0]),
                               rtol=1e-5, atol=1e-6)


def test_staleness_weight_rejects_unknown_kind():
    with pytest.raises(ValueError):
        staleness_weight(1, "exponential")


def test_weighted_buffer_mean_zero_weights_is_zero():
    """All-dropped buffer: the model must not move (zeros, not NaN)."""
    hat = {"g": jnp.ones((3, 5), jnp.float32)}
    out = weighted_buffer_mean([(0, hat, jnp.zeros(3, jnp.float32))])
    assert jnp.array_equal(out["g"], jnp.zeros(5, jnp.float32))


# ------------------------------------------------------- arrival determinism

_COMPUTE_CFG = D.ComputeTimeConfig(mean_s=0.5, speed_spread=0.4, jitter=0.3,
                                   straggler_prob=0.2, straggler_factor=5.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=12))
def test_compute_times_batching_independent(key_seed, m):
    """A client's compute draw depends on (key, client index) only —
    slicing the full-cohort draw equals drawing the subcohort."""
    key = jax.random.PRNGKey(key_seed)
    full = D.compute_times(key, _COMPUTE_CFG, 12)
    sub = D.compute_times(key, _COMPUTE_CFG, m)
    assert jnp.array_equal(full[:m], sub)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_compute_times_jit_matches_eager(key_seed):
    key = jax.random.PRNGKey(key_seed)
    eager = D.compute_times(key, _COMPUTE_CFG, 8)
    jitted = jax.jit(lambda k: D.compute_times(k, _COMPUTE_CFG, 8))(key)
    assert jnp.array_equal(eager, jitted)
    assert bool(jnp.all(eager > 0))


def test_compute_times_degenerate_is_exactly_mean():
    """The default config is the synchronous-equivalence model: every
    client computes in exactly ``mean_s`` seconds, no randomness."""
    key = jax.random.PRNGKey(7)
    t = D.compute_times(key, D.ComputeTimeConfig(), 6)
    assert jnp.array_equal(t, jnp.full(6, 1.0, jnp.float32))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=10))
def test_idle_gaps_batching_independent(key_seed, m):
    cfg = D.ArrivalConfig(mean_idle_s=2.0)
    key = jax.random.PRNGKey(key_seed)
    full = D.idle_gaps(key, 10, cfg)
    assert jnp.array_equal(full[:m], D.idle_gaps(key, m, cfg))
    assert bool(jnp.all(full >= 0))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.lists(st.booleans(), min_size=1, max_size=10))
def test_churn_step_batching_independent(key_seed, joined_bits):
    """Churn flips ride per-client fold_in lanes too: a client's fate is
    independent of the cohort it is drawn with."""
    cfg = D.ArrivalConfig(p_leave=0.3, p_rejoin=0.4)
    key = jax.random.PRNGKey(key_seed)
    joined = jnp.asarray(np.array(joined_bits, np.float32))
    m = joined.shape[0]
    padded = jnp.concatenate([joined, jnp.zeros(3, jnp.float32)])
    full = D.churn_step(key, padded, cfg)
    sub = D.churn_step(key, joined, cfg)
    assert jnp.array_equal(full[:m], sub)
    assert set(np.asarray(sub).tolist()) <= {0.0, 1.0}


def test_speed_factors_frozen_and_positive():
    key = jax.random.PRNGKey(3)
    cfg = D.ComputeTimeConfig(speed_spread=0.5)
    a = D.client_speed_factors(key, 8, cfg)
    b = D.client_speed_factors(key, 8, cfg)
    assert jnp.array_equal(a, b)
    assert bool(jnp.all(a > 0))
    # No spread -> exactly 1 (degenerate homogeneity).
    ones = D.client_speed_factors(key, 8, D.ComputeTimeConfig())
    assert jnp.array_equal(ones, jnp.ones(8, jnp.float32))


# ------------------------------------------------------- schedule stability


@pytest.mark.slow
def test_buffered_run_reproducible():
    """Same seed, same buffered run — accuracy, airtime, and the event
    clock are all deterministic despite host-side heap scheduling."""
    import dataclasses

    from repro.configs.mnist_cnn import config as cnn_config
    from repro.core import channel as CH
    from repro.core import transport as T
    from repro.data import synth_mnist
    from repro.fl import partition
    from repro.fl.async_engine import run_fl_buffered
    from repro.link import scenario as S

    (img, lab), (ti, tl) = synth_mnist.train_test(60, 16, seed=0)
    parts = partition.non_iid_partition(img, lab, n_clients=4)
    cx, cy = partition.stack_clients(parts, per_client=24)
    cfg = dataclasses.replace(cnn_config(), lr=0.1)
    tc = T.TransportConfig(mode="approx",
                           channel=CH.ChannelConfig(snr_db=10.0))
    scen = dataclasses.replace(S.get_scenario("metro-rush"),
                               ecrt_expected_tx=2.0)
    kw = dict(n_rounds=4, batch_per_round=8, eval_every=2, seed=11,
              scenario=scen, buffer_k=2, staleness="polynomial")
    a = run_fl_buffered(cfg, tc, cx, cy, ti, tl, **kw)
    b = run_fl_buffered(cfg, tc, cx, cy, ti, tl, **kw)
    assert a.accuracy == b.accuracy
    assert a.airtime_s == b.airtime_s
    assert a.event_s == b.event_s
    assert len(a.event_s) == len(a.rounds)
    assert all(t2 >= t1 for t1, t2 in zip(a.event_s, a.event_s[1:]))
