"""Fused approx-channel Pallas kernel vs the pure-jnp oracle.

Exactness (not allclose): kernel and ref share the counter-RNG, so outputs
must match bit-for-bit across every modulation / fading / shape swept here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops as O
from repro.kernels import ref as R

G0 = 1e-3  # tx_power * d^-alpha at d=10, alpha=3


def _run_both(x, seed, snr_db, k, fading, block_words):
    npow = G0 / (10 ** (snr_db / 10))
    ref_out, ref_err = R.ref_approx_channel(
        x, jnp.uint32(seed), jnp.float32(npow), jnp.float32(G0),
        bits_per_symbol=k, fading=fading, fade_block=64, block_words=block_words)
    ker_out, ker_err = O.approx_channel(
        x, jnp.uint32(seed), npow, G0, bits_per_symbol=k, fading=fading,
        fade_block=64, block_words=block_words, interpret=True)
    return ref_out, int(ref_err), ker_out, int(ker_err)


@pytest.mark.parametrize("k", [2, 4, 8])
@pytest.mark.parametrize("fading", ["rayleigh", "awgn", "block_rayleigh"])
def test_kernel_bitexact_vs_ref(k, fading):
    x = jax.random.uniform(jax.random.PRNGKey(0), (2048,), minval=-1, maxval=1)
    ref_out, ref_err, ker_out, ker_err = _run_both(x, 77, 10.0, k, fading, 512)
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(ker_out))
    assert ref_err == ker_err


@pytest.mark.parametrize("k", [2, 8])
@pytest.mark.parametrize("num_active", [1, 3, 5])
def test_masked_partial_batch_grid(k, num_active):
    """The masked (clients, tiles) grid: active rows bit-identical to the
    unmasked batch, masked tail rows all-zero with zero error count — the
    contract the adaptive dispatch's padded buckets rely on."""
    C, N = 5, 1024
    x = jax.random.uniform(jax.random.PRNGKey(3), (C, N), minval=-1, maxval=1)
    seeds = jnp.arange(100, 100 + C, dtype=jnp.uint32)
    npow = jnp.full((C,), G0 / 10.0, jnp.float32)
    gains = jnp.full((C,), G0, jnp.float32)
    full, full_err = O.approx_channel_batch(
        x, seeds, npow, gains, bits_per_symbol=k, block_words=512,
        interpret=True)
    part, part_err = O.approx_channel_batch(
        x, seeds, npow, gains, bits_per_symbol=k, block_words=512,
        interpret=True, num_active=jnp.int32(num_active))
    np.testing.assert_array_equal(
        np.asarray(full[:num_active]), np.asarray(part[:num_active]))
    np.testing.assert_array_equal(
        np.asarray(full_err[:num_active]), np.asarray(part_err[:num_active]))
    np.testing.assert_array_equal(
        np.asarray(part[num_active:]), np.zeros((C - num_active, N)))
    np.testing.assert_array_equal(
        np.asarray(part_err[num_active:]), np.zeros(C - num_active))


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([2, 4, 8]),
    st.sampled_from([128, 256, 1024]),
    st.integers(1, 6),  # payload in blocks
    st.sampled_from([0.0, 10.0, 25.0]),
)
def test_kernel_bitexact_sweep(seed, k, block_words, nblocks, snr):
    """Kernel == oracle, modulo rounding-boundary ties: the shared demod
    rounds (y*inv + L-1)/2, and XLA may fuse that differently (fma) in the
    vmapped reference vs the interpret-mode kernel, flipping the decision
    for symbols landing exactly on a decision boundary. Allow <=0.5% of
    elements to differ at such ties; everything else must be bit-exact."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (block_words * nblocks,), minval=-1.999, maxval=1.999)
    ref_out, ref_err, ker_out, ker_err = _run_both(x, seed, snr, k, "rayleigh", block_words)
    mism = np.asarray(ref_out) != np.asarray(ker_out)
    assert mism.mean() <= 0.005, f"{mism.sum()} / {mism.size} mismatches"
    assert abs(ref_err - ker_err) <= 32 * int(mism.sum())


def test_kernel_output_always_bounded():
    x = jax.random.uniform(jax.random.PRNGKey(3), (4096,), minval=-1.9, maxval=1.9)
    out, _ = O.approx_channel(x, jnp.uint32(5), G0 / 1.0, G0)  # SNR 0 dB
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).max()) < 2.0


def test_kernel_padding_path():
    """Non-multiple payloads go through ops.py padding."""
    x = jax.random.uniform(jax.random.PRNGKey(4), (1000,), minval=-1, maxval=1)
    out, errs = O.approx_channel(x, jnp.uint32(6), G0 / 10, G0, block_words=512)
    assert out.shape == (1000,)
    assert bool(jnp.isfinite(out).all())


def test_kernel_naive_mode_mask():
    """clamp_mask=0xFFFFFFFF reproduces naive (unbounded) transmission."""
    x = jax.random.uniform(jax.random.PRNGKey(5), (4096,), minval=-1, maxval=1)
    out, errs = O.approx_channel(
        x, jnp.uint32(7), G0 / 10, G0, clamp_mask=0xFFFFFFFF)
    assert errs > 0
    # without the clamp some decoded values exceed the bound (or are NaN)
    bad = (~jnp.isfinite(out)) | (jnp.abs(out) >= 2.0)
    assert bool(bad.any())


def test_demod_closed_form_equals_bruteforce_in_pipeline():
    """ref.py closed-form demod == modulation.demod_ml on the same symbols."""
    from repro.core import modulation as M

    for name in ("qpsk", "16qam", "256qam"):
        scheme = M.MOD_SCHEMES[name]
        key = jax.random.PRNGKey(8)
        y = (jax.random.normal(key, (1024,)) * 0.7 +
             1j * jax.random.normal(jax.random.PRNGKey(9), (1024,)) * 0.7
             ).astype(jnp.complex64)
        np.testing.assert_array_equal(
            np.asarray(M.demod_hard(y, scheme)), np.asarray(M.demod_ml(y, scheme)))


@pytest.mark.parametrize("k", [2, 4, 8])
def test_kernel_bf16_wire(k):
    """16-bit (bf16) wire variant: kernel == oracle, output bounded, and
    half the symbols per value vs the f32 wire."""
    x = jax.random.uniform(jax.random.PRNGKey(6), (2048,), minval=-1.9, maxval=1.9)
    ref_out, ref_err = R.ref_approx_channel(
        x, jnp.uint32(7), jnp.float32(G0 / 10), jnp.float32(G0),
        bits_per_symbol=k, fading="rayleigh", fade_block=64,
        clamp_mask=0xBFFF, block_words=512, word_bits=16)
    ker_out, ker_err = O.approx_channel(
        x, jnp.uint32(7), G0 / 10, G0, bits_per_symbol=k, clamp_mask=0xBFFF,
        block_words=512, word_bits=16, interpret=True)
    r32 = np.asarray(ref_out, np.float32)
    k32 = np.asarray(ker_out, np.float32)
    mism = (r32 != k32).mean()
    assert mism <= 0.005
    assert int(ref_err) == int(ker_err) or mism > 0
    assert np.isfinite(k32).all() and (np.abs(k32) < 2.0).all()
