"""Batched multi-client uplink engine: loop equivalence, per-client stats,
heterogeneous SNR, kernel path, and sharded dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as CH
from repro.core import transport as T

M, N = 8, 2048


def _cfg(**kw):
    ch = kw.pop("channel", CH.ChannelConfig(snr_db=10.0))
    return T.TransportConfig(channel=ch, **kw)


@pytest.fixture(scope="module")
def payloads():
    return jax.random.uniform(
        jax.random.PRNGKey(1), (M, N), minval=-0.99, maxval=0.99)


def _loop(payloads, key, cfg):
    """Reference: per-client transmit_flat under the same fold_in schedule."""
    outs, stats = [], []
    for i in range(payloads.shape[0]):
        o, s = T.transmit_flat(payloads[i], jax.random.fold_in(key, i), cfg)
        outs.append(o)
        stats.append(s)
    return jnp.stack(outs), stats


@pytest.mark.parametrize(
    "kw",
    [
        {"mode": "approx"},
        {"mode": "naive"},
        {"mode": "approx", "use_kernel": True},
        {"mode": "approx", "chunk_elems": 512},
        {"mode": "approx", "wire_dtype": "bfloat16"},
        {"mode": "perfect"},
        {"mode": "ecrt", "simulate_fec": False, "ecrt_expected_tx": 1.25},
    ],
    ids=lambda kw: "-".join(f"{k}={v}" for k, v in kw.items()),
)
def test_batch_equals_per_client_loop(payloads, kw):
    """(a) one fused transmit_batch == M transmit_flat calls, bit-for-bit on
    the received floats and exactly on the error counts, under the shared
    fold_in key schedule."""
    cfg = _cfg(**kw)
    key = jax.random.PRNGKey(2)
    bh, bs = T.transmit_batch(payloads, key, cfg)
    lh, ls = _loop(payloads, key, cfg)
    if kw["mode"] == "naive":
        # naive decodes NaNs; compare the bit patterns, not float equality
        np.testing.assert_array_equal(
            np.asarray(bh).view(np.uint32), np.asarray(lh).view(np.uint32))
    else:
        np.testing.assert_array_equal(np.asarray(bh), np.asarray(lh))
    np.testing.assert_array_equal(
        np.asarray(bs.bit_errors),
        np.array([float(s.bit_errors) for s in ls], np.float32))
    np.testing.assert_array_equal(
        np.asarray(bs.data_symbols),
        np.array([float(s.data_symbols) for s in ls], np.float32))


def test_batch_stats_shapes_and_units(payloads):
    """(b) TxStats fields are (M,) and respect the documented units."""
    cfg = _cfg(mode="approx")
    _, st = T.transmit_batch(payloads, jax.random.PRNGKey(3), cfg)
    for field in (st.data_symbols, st.transmissions, st.bit_errors, st.n_bits):
        assert field.shape == (M,)
    k = cfg.scheme.bits_per_symbol
    np.testing.assert_array_equal(np.asarray(st.n_bits), np.full(M, N * 32))
    np.testing.assert_array_equal(
        np.asarray(st.data_symbols), np.full(M, N * 32 // k))
    np.testing.assert_array_equal(np.asarray(st.transmissions), np.ones(M))
    assert st.ber.shape == (M,)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_heterogeneous_snr_monotonic_ber(payloads, use_kernel):
    """(c) per-client SNR: better links must see strictly fewer bit errors
    (SNR 0..35 dB spans BER ~2e-1 .. ~1e-4 — far beyond noise)."""
    snr = tuple(float(s) for s in np.linspace(0.0, 35.0, M))
    cfg = _cfg(mode="approx", use_kernel=use_kernel,
               channel=CH.ChannelConfig(snr_db=snr))
    _, st = T.transmit_batch(payloads, jax.random.PRNGKey(4), cfg)
    ber = np.asarray(st.ber)
    assert (ber[:-1] > ber[1:]).all(), ber


def test_heterogeneous_snr_override_equals_config(payloads):
    """snr_db= argument and per-client ChannelConfig.snr_db agree."""
    snr = jnp.linspace(0.0, 30.0, M)
    base = _cfg(mode="approx")
    via_cfg = _cfg(mode="approx",
                   channel=CH.ChannelConfig(snr_db=tuple(np.asarray(snr))))
    key = jax.random.PRNGKey(5)
    a, sa = T.transmit_batch(payloads, key, base, snr_db=snr)
    b, sb = T.transmit_batch(payloads, key, via_cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(sa.bit_errors), np.asarray(sb.bit_errors))


def test_batch_single_jitted_call(payloads):
    """The whole cohort runs inside one jit without retracing per client."""
    cfg = _cfg(mode="approx")
    fn = jax.jit(lambda x, k: T.transmit_batch(x, k, cfg))
    out, st = fn(payloads, jax.random.PRNGKey(6))
    assert out.shape == (M, N) and st.bit_errors.shape == (M,)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).max()) < 2.0


def test_pytree_batch_roundtrip_structure():
    tree = {
        "a": jnp.ones((M, 3, 5)),
        "b": [jnp.zeros((M, 7)), jnp.full((M, 2, 2), 0.5)],
    }
    out, st = T.transmit_pytree_batch(tree, jax.random.PRNGKey(7),
                                      _cfg(mode="perfect"))
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert st.bit_errors.shape == (M,)


def test_ecrt_real_batched_is_exact():
    x = jax.random.uniform(jax.random.PRNGKey(8), (3, 64), minval=-1, maxval=1)
    cfg = _cfg(mode="ecrt", channel=CH.ChannelConfig(snr_db=12.0), max_tx=6)
    out, st = T.transmit_batch(x, jax.random.PRNGKey(9), cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert st.transmissions.shape == (3,)
    assert float(jnp.sum(st.bit_errors)) == 0.0


def test_sharded_dispatch_matches_unsharded(payloads):
    """shard_map-over-mesh dispatch is bit-identical to the plain batch
    (globally-indexed fold_in keys), homogeneous and heterogeneous."""
    from repro.launch.sharding import shard_transmit_batch

    mesh = jax.make_mesh((1,), ("data",))  # 1 CPU device in the test runner
    cfg = _cfg(mode="approx")
    key = jax.random.PRNGKey(10)
    ref, rst = T.transmit_batch(payloads, key, cfg)
    out, ost = shard_transmit_batch(payloads, key, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(
        np.asarray(rst.bit_errors), np.asarray(ost.bit_errors))

    snr = jnp.linspace(0.0, 30.0, M)
    ref2, _ = T.transmit_batch(payloads, key, cfg, snr_db=snr)
    out2, _ = shard_transmit_batch(payloads, key, cfg, mesh, snr_db=snr)
    np.testing.assert_array_equal(np.asarray(ref2), np.asarray(out2))


def test_batch_snr_wrong_length_raises(payloads):
    """Regression: a per-client snr_db whose length != num_clients must fail
    loudly, naming both sizes — via the call override and the config path."""
    cfg = _cfg(mode="approx")
    key = jax.random.PRNGKey(20)
    with pytest.raises(ValueError, match=rf"{M - 1}.*{M} clients"):
        T.transmit_batch(payloads, key, cfg, snr_db=jnp.zeros((M - 1,)))
    bad_cfg = _cfg(mode="approx",
                   channel=CH.ChannelConfig(snr_db=tuple(range(M + 3))))
    with pytest.raises(ValueError, match=rf"{M + 3}.*{M}"):
        T.transmit_batch(payloads, key, bad_cfg)


def test_batch_snr_2d_raises(payloads):
    """A (2, M/2) grid flattens to M entries — it must be rejected, not
    silently reinterpreted as a per-client vector."""
    cfg = _cfg(mode="approx")
    with pytest.raises(ValueError, match="shape"):
        T.transmit_batch(payloads, jax.random.PRNGKey(21), cfg,
                         snr_db=jnp.zeros((2, M // 2)))


def _mode_table():
    ch = CH.ChannelConfig(snr_db=10.0)
    return (
        _cfg(mode="ecrt", channel=ch, simulate_fec=False, ecrt_expected_tx=2.2),
        _cfg(mode="approx", channel=ch),
        _cfg(mode="approx", modulation="16qam", channel=ch),
        _cfg(mode="approx", modulation="256qam", channel=ch),
    )


@pytest.mark.parametrize("dispatch", ["select", "bucketed"])
@pytest.mark.parametrize("with_snr", [False, True])
def test_adaptive_batch_equals_single_mode_calls(payloads, with_snr, dispatch):
    """A per-client mode vector is bit-identical to per-client single-mode
    ``transmit_flat`` calls under the shared fold_in key schedule — under
    either dispatch strategy (the bucketed key rides the client index, not
    the bucket slot)."""
    cfgs = _mode_table()
    key = jax.random.PRNGKey(22)
    mode = jnp.array([0, 1, 2, 3, 3, 2, 1, 0])
    snr = jnp.linspace(4.0, 30.0, M) if with_snr else None
    out, st = T.transmit_batch_adaptive(payloads, key, cfgs, mode, snr_db=snr,
                                        dispatch=dispatch)
    for i in range(M):
        cfg_i = cfgs[int(mode[i])]
        s_i = None if snr is None else snr[i]
        ref, rst = T.transmit_flat(payloads[i], jax.random.fold_in(key, i),
                                   cfg_i, snr_db=s_i)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(ref))
        assert float(st.bit_errors[i]) == float(rst.bit_errors)
        assert float(st.data_symbols[i]) == float(rst.data_symbols)
    np.testing.assert_array_equal(np.asarray(st.mode_idx), np.asarray(mode))


def test_adaptive_uniform_mode_equals_plain_batch(payloads):
    """An all-one-mode vector reproduces transmit_batch exactly."""
    cfgs = _mode_table()
    key = jax.random.PRNGKey(23)
    for m in (1, 2):
        out, st = T.transmit_batch_adaptive(
            payloads, key, cfgs, jnp.full((M,), m, jnp.int32))
        ref, rst = T.transmit_batch(payloads, key, cfgs[m])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        np.testing.assert_array_equal(
            np.asarray(st.bit_errors), np.asarray(rst.bit_errors))


def test_adaptive_single_jit_trace(payloads):
    """Mixed-mode cohorts re-dispatch without retracing: one XLA program."""
    cfgs = _mode_table()
    traces = []

    def fn(x, k, mode):
        traces.append(1)
        return T.transmit_batch_adaptive(x, k, cfgs, mode)

    jf = jax.jit(fn)
    for seed in (0, 1, 2):
        mode = jax.random.randint(jax.random.PRNGKey(seed), (M,), 0, len(cfgs))
        out, st = jf(payloads, jax.random.PRNGKey(24), mode)
        assert out.shape == (M, N)
    assert len(traces) == 1


def test_adaptive_validation_errors(payloads):
    cfgs = _mode_table()
    key = jax.random.PRNGKey(25)
    with pytest.raises(ValueError, match="mode_idx"):
        T.transmit_batch_adaptive(payloads, key, cfgs, jnp.zeros((M - 2,), jnp.int32))
    # Kernel rows are rejected only on the select dispatch (the Pallas grid
    # cannot lower inside a vmapped switch); bucketed accepts them.
    with pytest.raises(ValueError, match="use_kernel"):
        T.transmit_batch_adaptive(
            payloads, key, (_cfg(mode="approx", use_kernel=True),),
            jnp.zeros((M,), jnp.int32), dispatch="select")
    mixed_ch = (_cfg(mode="approx"),
                _cfg(mode="approx", channel=CH.ChannelConfig(snr_db=20.0)))
    with pytest.raises(ValueError, match="ChannelConfig"):
        T.transmit_batch_adaptive(payloads, key, mixed_ch,
                                  jnp.zeros((M,), jnp.int32))
    with pytest.raises(ValueError, match="dispatch"):
        T.transmit_batch_adaptive(payloads, key, cfgs,
                                  jnp.zeros((M,), jnp.int32), dispatch="warp")


def test_adaptive_kernel_rows_accepted_on_bucketed(payloads):
    """The un-banned Pallas path: use_kernel rows dispatch per client via
    mode buckets, each row bit-identical to the per-client kernel call."""
    ch = CH.ChannelConfig(snr_db=10.0)
    cfgs = (
        _cfg(mode="ecrt", channel=ch, simulate_fec=False,
             ecrt_expected_tx=2.2),
        _cfg(mode="approx", channel=ch, use_kernel=True),
        _cfg(mode="approx", modulation="16qam", channel=ch, use_kernel=True),
    )
    key = jax.random.PRNGKey(30)
    mode = jnp.array([0, 1, 2, 1, 2, 0, 1, 1])
    snr = jnp.linspace(5.0, 25.0, M)
    out, st = T.transmit_batch_adaptive(payloads, key, cfgs, mode, snr_db=snr)
    for i in range(M):
        ref, rst = T.transmit_flat(payloads[i], jax.random.fold_in(key, i),
                                   cfgs[int(mode[i])], snr_db=snr[i])
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(ref))
        assert float(st.bit_errors[i]) == float(rst.bit_errors)


@pytest.mark.parametrize("dispatch", ["select", "bucketed"])
def test_adaptive_out_of_range_modes_clamp_consistently(payloads, dispatch):
    """Out-of-range mode indices clamp for dispatch AND for the recorded
    stats.mode_idx — a stray -1 must not transmit as cfgs[0] yet price as
    the last row (negative jnp indexing wraps)."""
    cfgs = _mode_table()
    key = jax.random.PRNGKey(51)
    wild = np.array([-1, 0, 1, 2, 3, 9, -5, 2], np.int32)
    clamped = np.clip(wild, 0, len(cfgs) - 1)
    out, st = T.transmit_batch_adaptive(payloads, key, cfgs, wild,
                                        dispatch=dispatch)
    ref, rst = T.transmit_batch_adaptive(payloads, key, cfgs, clamped,
                                         dispatch=dispatch)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(st.mode_idx), clamped)


def test_adaptive_empty_cohort_agrees_across_dispatches():
    """A fully-dropped round (zero clients) must return empty results from
    both dispatches instead of crashing on zero buckets."""
    cfgs = _mode_table()
    x0 = jnp.zeros((0, 64), jnp.float32)
    m0 = np.zeros((0,), np.int32)
    for dispatch in ("select", "bucketed"):
        out, st = T.transmit_batch_adaptive(
            x0, jax.random.PRNGKey(50), cfgs, m0, dispatch=dispatch)
        assert out.shape == (0, 64)
        for f in (st.data_symbols, st.transmissions, st.bit_errors, st.n_bits):
            assert f.shape == (0,)
        assert st.mode_idx.shape == (0,)


def test_adaptive_bucketed_inside_jit_raises(payloads):
    """An explicitly-requested bucketed dispatch under a traced mode vector
    must fail loudly (bucket sizes are host-side), naming the escape hatch."""
    cfgs = _mode_table()

    @jax.jit
    def fn(x, k, m):
        return T.transmit_batch_adaptive(x, k, cfgs, m, dispatch="bucketed")

    with pytest.raises(ValueError, match="concrete mode_idx"):
        fn(payloads, jax.random.PRNGKey(31), jnp.zeros((M,), jnp.int32))


def test_adaptive_airtime_matches_static_pricing(payloads):
    """round_airtime_adaptive == round_airtime per mode on uniform batches."""
    from repro.core import latency as LAT

    cfgs = _mode_table()
    t = LAT.PhyTimings()
    key = jax.random.PRNGKey(26)
    for m, mode_name in ((0, "ecrt"), (1, "approx")):
        _, st = T.transmit_batch_adaptive(
            payloads, key, cfgs, jnp.full((M,), m, jnp.int32))
        adaptive = np.asarray(LAT.round_airtime_adaptive(st, t, cfgs))
        static = np.asarray(LAT.round_airtime(st, t, mode_name))
        np.testing.assert_allclose(adaptive, static, rtol=1e-6)
    _, st_plain = T.transmit_batch(payloads, key, cfgs[1])
    with pytest.raises(ValueError, match="mode_idx"):
        LAT.round_airtime_adaptive(st_plain, t, cfgs)


def test_client_offset_windows_the_schedule(payloads):
    """client_offset reproduces any contiguous slice of a larger batch —
    the property the sharded dispatch relies on."""
    cfg = _cfg(mode="approx")
    key = jax.random.PRNGKey(11)
    full, _ = T.transmit_batch(payloads, key, cfg)
    lo, _ = T.transmit_batch(payloads[: M // 2], key, cfg)
    hi, _ = T.transmit_batch(payloads[M // 2 :], key, cfg,
                             client_offset=M // 2)
    np.testing.assert_array_equal(
        np.asarray(full), np.concatenate([np.asarray(lo), np.asarray(hi)]))


def test_adaptive_client_offset_windows_the_schedule(payloads):
    """The bucketed dispatch keeps the fold_in key on the *global* client
    index: any contiguous slice with the matching offset reproduces the full
    batch (the invariant the sharded adaptive dispatch builds on)."""
    cfgs = _mode_table()
    key = jax.random.PRNGKey(32)
    mode = np.array([0, 1, 2, 3, 1, 2, 0, 3], np.int32)
    full, _ = T.transmit_batch_adaptive(payloads, key, cfgs, mode)
    lo, _ = T.transmit_batch_adaptive(payloads[: M // 2], key, cfgs,
                                      mode[: M // 2])
    hi, _ = T.transmit_batch_adaptive(payloads[M // 2 :], key, cfgs,
                                      mode[M // 2 :], client_offset=M // 2)
    np.testing.assert_array_equal(
        np.asarray(full), np.concatenate([np.asarray(lo), np.asarray(hi)]))


# ----------------------------------------------- bucketed ≡ select coverage


def _preset_round_modes(preset: str, num_clients: int):
    """Draw a (snr, mode) vector from a scenario preset's dynamics through
    the default threshold policy — realistic mixed-mode rounds per preset."""
    import zlib

    from repro.link import dynamics as D
    from repro.link import policy as P

    scen_dyn = D.DYNAMICS_PRESETS[preset]
    seed = zlib.crc32(preset.encode()) % 2**31  # stable across processes
    snr = D.trajectory(jax.random.PRNGKey(seed), scen_dyn, num_clients, 2)[-1]
    mode = np.asarray(P.initial_mode(snr, P.PolicyConfig()))
    return snr, mode


@pytest.mark.parametrize("preset", ["static", "pedestrian", "vehicular",
                                    "shadowed-urban", "bursty",
                                    "iot-lowrate"])
@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
def test_bucketed_equals_select_across_presets(preset, wire_dtype):
    """Bucketed ≡ select, bit for bit, on mode mixes drawn from every
    scenario preset's dynamics, for both wire dtypes."""
    from repro.link import policy as P

    n, n_floats = 12, 256
    snr, mode = _preset_round_modes(preset, n)
    cfgs = P.build_mode_cfgs(
        _cfg(wire_dtype=wire_dtype), P.PolicyConfig(), ecrt_expected_tx=2.0)
    x = jax.random.uniform(jax.random.PRNGKey(33), (n, n_floats),
                           minval=-0.99, maxval=0.99)
    key = jax.random.PRNGKey(34)
    a, sa = T.transmit_batch_adaptive(x, key, cfgs, mode, snr_db=snr,
                                      dispatch="select")
    b, sb = T.transmit_batch_adaptive(x, key, cfgs, mode, snr_db=snr,
                                      dispatch="bucketed")
    np.testing.assert_array_equal(
        np.asarray(a).view(np.uint32), np.asarray(b).view(np.uint32))
    for f in ("data_symbols", "transmissions", "bit_errors", "n_bits"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sa, f)), np.asarray(getattr(sb, f)))


def test_bucketed_equals_select_with_chunked_rows(payloads):
    """Mode tables whose rows chunk the payload (chunk_elems) dispatch
    identically under both strategies, including a payload length that does
    not divide the chunk size."""
    x = payloads[:, : 1500]  # 1500 % 512 != 0 -> padded chunked pipeline
    cfgs = (_cfg(mode="approx", chunk_elems=512), _cfg(mode="approx"))
    mode = np.array([0, 1, 0, 1, 1, 0, 0, 1], np.int32)
    key = jax.random.PRNGKey(35)
    a, sa = T.transmit_batch_adaptive(x, key, cfgs, mode, dispatch="select")
    b, sb = T.transmit_batch_adaptive(x, key, cfgs, mode, dispatch="bucketed")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(sa.bit_errors), np.asarray(sb.bit_errors))


# ------------------------------------------------- chunked-path equivalence


@pytest.mark.parametrize("n_payload", [1500, 2048, 513])
@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
def test_chunked_batch_equals_chunked_flat_loop(n_payload, wire_dtype):
    """Chunked uplinks (incl. lengths not divisible by chunk_elems) stay
    bit-identical between the fused batch and a per-client flat loop."""
    cfg = _cfg(mode="approx", chunk_elems=512, wire_dtype=wire_dtype)
    x = jax.random.uniform(jax.random.PRNGKey(36), (4, n_payload),
                           minval=-0.99, maxval=0.99)
    key = jax.random.PRNGKey(37)
    bh, bs = T.transmit_batch(x, key, cfg)
    lh, ls = _loop(x, key, cfg)
    np.testing.assert_array_equal(np.asarray(bh), np.asarray(lh))
    np.testing.assert_array_equal(
        np.asarray(bs.bit_errors),
        np.array([float(s.bit_errors) for s in ls], np.float32))


@pytest.mark.parametrize("n_payload", [1500, 513])
def test_chunked_stats_consistent_with_direct_recount(n_payload):
    """The chunked pipeline's pad-error subtraction: reported bit_errors
    must equal a direct popcount of sent-vs-received words over the true
    payload only, for lengths that force padding."""
    from repro.core import float_codec as fc
    from repro.core import modulation as mod_lib

    cfg = _cfg(mode="naive", chunk_elems=512)  # no clamp: errors survive
    x = jax.random.uniform(jax.random.PRNGKey(38), (n_payload,),
                           minval=-0.99, maxval=0.99)
    x_hat, st = T.transmit_flat(x, jax.random.PRNGKey(39), cfg)
    direct = int(jnp.sum(mod_lib.popcount(
        fc.f32_to_bits(x) ^ fc.f32_to_bits(x_hat))))
    assert int(st.bit_errors) == direct
    assert int(st.n_bits) == n_payload * 32
    k = cfg.scheme.bits_per_symbol
    assert int(st.data_symbols) == n_payload * 32 // k


# -------------------------------------------------- _same_channel semantics


def test_same_channel_normalizes_snr_shapes():
    """Regression: scalar vs 0-d array vs length-1 sequence snr_db all mean
    one homogeneous SNR and must compare equal; genuinely different values
    or lengths must not."""
    same = [
        CH.ChannelConfig(snr_db=10.0),
        CH.ChannelConfig(snr_db=np.float32(10.0)),
        CH.ChannelConfig(snr_db=np.array(10.0)),
        CH.ChannelConfig(snr_db=(10.0,)),
        CH.ChannelConfig(snr_db=[10.0]),
    ]
    for a in same:
        for b in same:
            assert T._same_channel(a, b), (a.snr_db, b.snr_db)
    base = same[0]
    assert not T._same_channel(base, CH.ChannelConfig(snr_db=11.0))
    assert not T._same_channel(base, CH.ChannelConfig(snr_db=(10.0, 11.0)))
    assert not T._same_channel(
        CH.ChannelConfig(snr_db=(10.0, 11.0)),
        CH.ChannelConfig(snr_db=(10.0, 11.0, 12.0)))
    # size-1 broadcasts against a longer constant vector
    assert T._same_channel(base, CH.ChannelConfig(snr_db=(10.0, 10.0)))


def test_bucketed_canonicalizes_array_snr_for_jit_cache(payloads):
    """An array-valued channel snr_db must not silently disable the
    per-mode jit cache: it canonicalizes to a tuple, matching the
    tuple-configured table bit for bit and sharing its cache entry."""
    snr = np.linspace(0.0, 21.0, M).astype(np.float32)
    cfg_arr = _cfg(mode="approx",
                   channel=CH.ChannelConfig(snr_db=np.array(snr)))
    cfg_tup = _cfg(mode="approx",
                   channel=CH.ChannelConfig(snr_db=tuple(float(s) for s in snr)))
    key = jax.random.PRNGKey(52)
    mode = np.zeros((M,), np.int32)
    misses0 = T._cached_mode_batch_fn.cache_info().misses
    a, _ = T.transmit_batch_adaptive(payloads, key, (cfg_arr,), mode)
    b, _ = T.transmit_batch_adaptive(payloads, key, (cfg_tup,), mode)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    info = T._cached_mode_batch_fn.cache_info()
    # One shared entry: the array-config call populated it (miss), the
    # tuple-config call reused it (hit) — no TypeError fallback.
    assert info.misses == misses0 + 1


def test_adaptive_accepts_shape_normalized_channels(payloads):
    """A mode table mixing scalar and length-1 snr_db representations of the
    same channel must dispatch (and match the all-scalar table exactly)."""
    mixed = (_cfg(mode="approx"),
             _cfg(mode="approx", modulation="16qam",
                  channel=CH.ChannelConfig(snr_db=(10.0,))))
    uniform = (_cfg(mode="approx"),
               _cfg(mode="approx", modulation="16qam"))
    key = jax.random.PRNGKey(40)
    mode = np.array([0, 1] * (M // 2), np.int32)
    a, _ = T.transmit_batch_adaptive(payloads, key, mixed, mode)
    b, _ = T.transmit_batch_adaptive(payloads, key, uniform, mode)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_select_consumers_clear_kernel_rows(payloads):
    """Regression: a kernel-enabled mode table must not brick the
    select-pinned consumers (fused FL round, shard_map) — they clear
    ``use_kernel`` themselves (PR-2 behavior) instead of hitting the
    engine's ValueError."""
    from repro.fl.loop import select_mode_cfgs
    from repro.launch.sharding import shard_transmit_batch_adaptive
    from repro.link import policy as P

    ch = CH.ChannelConfig(snr_db=10.0)
    kernel_cfgs = P.build_mode_cfgs(
        T.TransportConfig(channel=ch, use_kernel=True), P.PolicyConfig(),
        ecrt_expected_tx=2.0)
    assert any(c.use_kernel for c in kernel_cfgs)

    class FakeDriver:
        mode_cfgs = kernel_cfgs

    cleared = select_mode_cfgs(FakeDriver())
    assert all(not c.use_kernel for c in cleared)

    mode = np.array([0, 1, 2, 3, 3, 2, 1, 0], np.int32)
    key = jax.random.PRNGKey(42)
    # The sharded dispatch accepts the kernel table (clearing internally)
    # and matches the cleared-table reference bit for bit.
    mesh = jax.make_mesh((1,), ("data",))
    out, _ = shard_transmit_batch_adaptive(payloads, key, kernel_cfgs, mode,
                                           mesh)
    ref, _ = T.transmit_batch_adaptive(payloads, key, cleared, mode)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_sharded_adaptive_matches_unsharded(payloads):
    """shard_map adaptive dispatch == unsharded call, homogeneous and
    heterogeneous SNR, on a 1-device mesh."""
    from repro.launch.sharding import shard_transmit_batch_adaptive

    mesh = jax.make_mesh((1,), ("data",))
    cfgs = _mode_table()
    key = jax.random.PRNGKey(41)
    mode = np.array([0, 1, 2, 3, 3, 2, 1, 0], np.int32)
    snr = jnp.linspace(2.0, 28.0, M)
    ref, rst = T.transmit_batch_adaptive(payloads, key, cfgs, mode,
                                         snr_db=snr)
    out, ost = shard_transmit_batch_adaptive(payloads, key, cfgs, mode, mesh,
                                             snr_db=snr)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(
        np.asarray(rst.bit_errors), np.asarray(ost.bit_errors))
    np.testing.assert_array_equal(
        np.asarray(rst.mode_idx), np.asarray(ost.mode_idx))

    ref2, _ = T.transmit_batch_adaptive(payloads, key, cfgs, mode)
    out2, _ = shard_transmit_batch_adaptive(payloads, key, cfgs, mode, mesh)
    np.testing.assert_array_equal(np.asarray(ref2), np.asarray(out2))
