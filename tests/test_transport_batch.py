"""Batched multi-client uplink engine: loop equivalence, per-client stats,
heterogeneous SNR, kernel path, and sharded dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as CH
from repro.core import transport as T

M, N = 8, 2048


def _cfg(**kw):
    ch = kw.pop("channel", CH.ChannelConfig(snr_db=10.0))
    return T.TransportConfig(channel=ch, **kw)


@pytest.fixture(scope="module")
def payloads():
    return jax.random.uniform(
        jax.random.PRNGKey(1), (M, N), minval=-0.99, maxval=0.99)


def _loop(payloads, key, cfg):
    """Reference: per-client transmit_flat under the same fold_in schedule."""
    outs, stats = [], []
    for i in range(payloads.shape[0]):
        o, s = T.transmit_flat(payloads[i], jax.random.fold_in(key, i), cfg)
        outs.append(o)
        stats.append(s)
    return jnp.stack(outs), stats


@pytest.mark.parametrize(
    "kw",
    [
        {"mode": "approx"},
        {"mode": "naive"},
        {"mode": "approx", "use_kernel": True},
        {"mode": "approx", "chunk_elems": 512},
        {"mode": "approx", "wire_dtype": "bfloat16"},
        {"mode": "perfect"},
        {"mode": "ecrt", "simulate_fec": False, "ecrt_expected_tx": 1.25},
    ],
    ids=lambda kw: "-".join(f"{k}={v}" for k, v in kw.items()),
)
def test_batch_equals_per_client_loop(payloads, kw):
    """(a) one fused transmit_batch == M transmit_flat calls, bit-for-bit on
    the received floats and exactly on the error counts, under the shared
    fold_in key schedule."""
    cfg = _cfg(**kw)
    key = jax.random.PRNGKey(2)
    bh, bs = T.transmit_batch(payloads, key, cfg)
    lh, ls = _loop(payloads, key, cfg)
    if kw["mode"] == "naive":
        # naive decodes NaNs; compare the bit patterns, not float equality
        np.testing.assert_array_equal(
            np.asarray(bh).view(np.uint32), np.asarray(lh).view(np.uint32))
    else:
        np.testing.assert_array_equal(np.asarray(bh), np.asarray(lh))
    np.testing.assert_array_equal(
        np.asarray(bs.bit_errors),
        np.array([float(s.bit_errors) for s in ls], np.float32))
    np.testing.assert_array_equal(
        np.asarray(bs.data_symbols),
        np.array([float(s.data_symbols) for s in ls], np.float32))


def test_batch_stats_shapes_and_units(payloads):
    """(b) TxStats fields are (M,) and respect the documented units."""
    cfg = _cfg(mode="approx")
    _, st = T.transmit_batch(payloads, jax.random.PRNGKey(3), cfg)
    for field in (st.data_symbols, st.transmissions, st.bit_errors, st.n_bits):
        assert field.shape == (M,)
    k = cfg.scheme.bits_per_symbol
    np.testing.assert_array_equal(np.asarray(st.n_bits), np.full(M, N * 32))
    np.testing.assert_array_equal(
        np.asarray(st.data_symbols), np.full(M, N * 32 // k))
    np.testing.assert_array_equal(np.asarray(st.transmissions), np.ones(M))
    assert st.ber.shape == (M,)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_heterogeneous_snr_monotonic_ber(payloads, use_kernel):
    """(c) per-client SNR: better links must see strictly fewer bit errors
    (SNR 0..35 dB spans BER ~2e-1 .. ~1e-4 — far beyond noise)."""
    snr = tuple(float(s) for s in np.linspace(0.0, 35.0, M))
    cfg = _cfg(mode="approx", use_kernel=use_kernel,
               channel=CH.ChannelConfig(snr_db=snr))
    _, st = T.transmit_batch(payloads, jax.random.PRNGKey(4), cfg)
    ber = np.asarray(st.ber)
    assert (ber[:-1] > ber[1:]).all(), ber


def test_heterogeneous_snr_override_equals_config(payloads):
    """snr_db= argument and per-client ChannelConfig.snr_db agree."""
    snr = jnp.linspace(0.0, 30.0, M)
    base = _cfg(mode="approx")
    via_cfg = _cfg(mode="approx",
                   channel=CH.ChannelConfig(snr_db=tuple(np.asarray(snr))))
    key = jax.random.PRNGKey(5)
    a, sa = T.transmit_batch(payloads, key, base, snr_db=snr)
    b, sb = T.transmit_batch(payloads, key, via_cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(sa.bit_errors), np.asarray(sb.bit_errors))


def test_batch_single_jitted_call(payloads):
    """The whole cohort runs inside one jit without retracing per client."""
    cfg = _cfg(mode="approx")
    fn = jax.jit(lambda x, k: T.transmit_batch(x, k, cfg))
    out, st = fn(payloads, jax.random.PRNGKey(6))
    assert out.shape == (M, N) and st.bit_errors.shape == (M,)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out).max()) < 2.0


def test_pytree_batch_roundtrip_structure():
    tree = {
        "a": jnp.ones((M, 3, 5)),
        "b": [jnp.zeros((M, 7)), jnp.full((M, 2, 2), 0.5)],
    }
    out, st = T.transmit_pytree_batch(tree, jax.random.PRNGKey(7),
                                      _cfg(mode="perfect"))
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert st.bit_errors.shape == (M,)


def test_ecrt_real_batched_is_exact():
    x = jax.random.uniform(jax.random.PRNGKey(8), (3, 64), minval=-1, maxval=1)
    cfg = _cfg(mode="ecrt", channel=CH.ChannelConfig(snr_db=12.0), max_tx=6)
    out, st = T.transmit_batch(x, jax.random.PRNGKey(9), cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert st.transmissions.shape == (3,)
    assert float(jnp.sum(st.bit_errors)) == 0.0


def test_sharded_dispatch_matches_unsharded(payloads):
    """shard_map-over-mesh dispatch is bit-identical to the plain batch
    (globally-indexed fold_in keys), homogeneous and heterogeneous."""
    from repro.launch.sharding import shard_transmit_batch

    mesh = jax.make_mesh((1,), ("data",))  # 1 CPU device in the test runner
    cfg = _cfg(mode="approx")
    key = jax.random.PRNGKey(10)
    ref, rst = T.transmit_batch(payloads, key, cfg)
    out, ost = shard_transmit_batch(payloads, key, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    np.testing.assert_array_equal(
        np.asarray(rst.bit_errors), np.asarray(ost.bit_errors))

    snr = jnp.linspace(0.0, 30.0, M)
    ref2, _ = T.transmit_batch(payloads, key, cfg, snr_db=snr)
    out2, _ = shard_transmit_batch(payloads, key, cfg, mesh, snr_db=snr)
    np.testing.assert_array_equal(np.asarray(ref2), np.asarray(out2))


def test_client_offset_windows_the_schedule(payloads):
    """client_offset reproduces any contiguous slice of a larger batch —
    the property the sharded dispatch relies on."""
    cfg = _cfg(mode="approx")
    key = jax.random.PRNGKey(11)
    full, _ = T.transmit_batch(payloads, key, cfg)
    lo, _ = T.transmit_batch(payloads[: M // 2], key, cfg)
    hi, _ = T.transmit_batch(payloads[M // 2 :], key, cfg,
                             client_offset=M // 2)
    np.testing.assert_array_equal(
        np.asarray(full), np.concatenate([np.asarray(lo), np.asarray(hi)]))
