"""Gradient-bound certificates (paper Sec. III) vs brute-force gradients."""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import bounds as B


def test_final_layer_delta_bound():
    layers = [B.LayerSpec(8, "sigmoid"), B.LayerSpec(10, "softmax_xent")]
    bs = B.gradient_bound(layers, input_bound=1.0)
    assert all(b > 0 for b in bs)


def test_relu_is_uncertified():
    layers = [B.LayerSpec(8, "relu"), B.LayerSpec(10, "softmax_xent")]
    assert B.certified_clamp_bound(layers) == 2.0  # falls back to paper default


def test_certificate_dominates_empirical_gradient():
    """Build the paper's setting (sigmoid hidden, softmax+xent out, |w|<1)
    and check max|dC/dw| over random draws <= the Sec. III certificate."""
    sizes = [6, 5, 4]  # input 6 -> hidden 5 -> classes 4
    layers = [B.LayerSpec(5, "sigmoid", 1.0), B.LayerSpec(4, "softmax_xent", 1.0)]
    cert = B.gradient_bound(layers, input_bound=1.0)

    def loss(params, x, y):
        w1, w2 = params
        a1 = jax.nn.sigmoid(x @ w1)
        logits = a1 @ w2
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * y, -1))

    key = jax.random.PRNGKey(0)
    worst = [0.0, 0.0]
    for i in range(20):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        w1 = jax.random.uniform(k1, (6, 5), minval=-1, maxval=1)
        w2 = jax.random.uniform(k2, (5, 4), minval=-1, maxval=1)
        x = jax.random.uniform(k3, (16, 6), minval=-1, maxval=1)
        y = jax.nn.one_hot(jax.random.randint(k4, (16,), 0, 4), 4)
        g1, g2 = jax.grad(loss)((w1, w2), x, y)
        worst[0] = max(worst[0], float(jnp.abs(g1).max()))
        worst[1] = max(worst[1], float(jnp.abs(g2).max()))
    assert worst[0] <= cert[0]
    assert worst[1] <= cert[1]
    # and the empirical |g| is, as the paper observes, well below 1
    assert max(worst) < 1.0


def test_clamp_bound_power_of_two():
    layers = [B.LayerSpec(4, "sigmoid"), B.LayerSpec(4, "softmax_xent")]
    b = B.certified_clamp_bound(layers)
    assert b <= 2.0 and math.log2(b) == int(math.log2(b))
