"""Property tests for the float <-> symbol codec (paper Sec. IV-A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import float_codec as FC

BITS_PER_SYMBOL = [2, 4, 8]

finite_floats = st.lists(
    st.floats(min_value=-1.9375, max_value=1.9375, allow_nan=False, width=32),
    min_size=1, max_size=64,
)


@settings(max_examples=30, deadline=None)
@given(finite_floats, st.sampled_from(BITS_PER_SYMBOL))
def test_word_symbol_roundtrip(vals, k):
    x = jnp.asarray(vals, jnp.float32)
    u = FC.f32_to_bits(x)
    sym = FC.words_to_symbols(u, k)
    assert sym.shape == (len(vals), 32 // k)
    assert int(sym.max()) < (1 << k)
    back = FC.symbols_to_words(sym, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(u))


@settings(max_examples=30, deadline=None)
@given(finite_floats, st.sampled_from(BITS_PER_SYMBOL))
def test_interleaver_is_bijective(vals, k):
    x = jnp.asarray(vals, jnp.float32)
    sym = FC.words_to_symbols(FC.f32_to_bits(x), k)
    stream = FC.interleave(sym)
    assert stream.shape == (sym.size,)
    back = FC.deinterleave(stream, sym.shape[0], sym.shape[1])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(sym))
    # column-major property: consecutive stream symbols come from
    # consecutive *words* (burst spreading), not the same word
    if sym.shape[0] > 1:
        assert int(stream[0]) == int(sym[0, 0]) and int(stream[1]) == int(sym[1, 0])


def test_bit30_clamp_bounds_everything():
    # every possible exponent pattern, incl. NaN/Inf encodings
    u = jnp.arange(0, 2**16, dtype=jnp.uint32) << 16
    clamped = FC.bits_to_f32(FC.clamp_exponent_bits(u, 2.0))
    assert bool(jnp.isfinite(clamped).all())
    assert float(jnp.abs(clamped).max()) < 2.0


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-1.9375, max_value=1.9375, allow_nan=False, width=32))
def test_clamp_is_identity_on_valid_gradients(v):
    """Values already in (-2, 2) pass through the receiver clamp unchanged."""
    u = FC.f32_to_bits(jnp.asarray([v], jnp.float32))
    out = FC.bits_to_f32(FC.clamp_exponent_bits(u, 2.0))
    assert float(out[0]) == pytest.approx(v, abs=0.0)


def test_clamp_idempotent():
    u = jnp.arange(0, 1 << 14, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
    once = FC.clamp_exponent_bits(u, 2.0)
    twice = FC.clamp_exponent_bits(once, 2.0)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@pytest.mark.parametrize("bound,cleared", [(2.0, 1), (1.0, 1), (2.0**-64, 2),
                                           (2.0**-126, 7)])
def test_exponent_mask_tightens_with_bound(bound, cleared):
    mask = FC.exponent_clamp_mask(bound)
    n_cleared = sum(1 for b in range(23, 31) if not (mask >> b) & 1)
    assert n_cleared == cleared
    assert (mask >> 31) & 1 == 1  # sign bit never cleared
